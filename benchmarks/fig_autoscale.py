"""Reactive vs predictive fleet autoscaling on a flash crowd (ISSUE 10).

The same seeded trace — a steady Zipf base mix plus a flash crowd on a
nearly-cold model (trickle -> ramp -> peak -> gone) — is served twice
with identical knobs except the forecast:

  * **reactive** — ``predict_target`` with the trend zeroed: the
    autoscaler only sees load that has already arrived, so new nodes
    start their checkpoint-restore warm-up *after* the crowd is
    already burning SLOs.
  * **predictive** — the shared EWMA + within-window-growth forecast
    extrapolates the ramp, so pre-warming (priced per node by the
    ``RestoreCostModel``: model bytes / storage bandwidth, not a flat
    constant) starts an epoch or more earlier and capacity is routable
    when the peak lands.

Both arms pay real restore cost and both scale back down once the crowd
leaves (the stale-EWMA decay fix is what lets the forecast fall), so the
comparison is attainment *and* efficiency: gold-class SLO attainment and
goodput per node-hour.  Results merge into ``BENCH_fabric.json`` under
the ``"autoscale"`` key.

CLI: ``python -m benchmarks.fig_autoscale --tiny`` runs a 3-node CI
smoke and exits non-zero on a conservation break or a predictive loss.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import (Row, add_trace_dir_arg, maybe_attach_timeline,
                               maybe_dump_run, merge_bench_json,
                               set_trace_dir, setup)
from repro.core.scenarios import flash_crowd_scenario
from repro.fabric import (FabricConfig, RestoreCostModel, build_fabric,
                          build_trace_soa)
from repro.fabric.priority import CLASS_NAMES

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fabric.json")

HORIZON_S = 40.0
NODE_COUNTS = (4,)
TRACE_SEED = 11
EPOCH_MS = 2_000.0


def _scenario(n_nodes: int, horizon_s: float):
    """Flash crowd with its phases scaled to the horizon: quiet for the
    first 30%, ramping over 10%, peaking until 75%, then gone.  The
    crowd is sized in *solver* capacity, not sweep units: a 4-GPU node
    schedules ~1.6k vgg req/s, so ``9 * n_nodes`` sweep units (1.8k
    req/s per fleet node) is a crowd the starting fleet genuinely
    cannot host and the autoscaler must grow into."""
    return flash_crowd_scenario(
        n_nodes, horizon_s=horizon_s,
        t0_s=0.30 * horizon_s, ramp_s=0.10 * horizon_s,
        t1_s=0.75 * horizon_s, crowd_units=9.0 * n_nodes)


def _cfg(mode: str, n_nodes: int, horizon_s: float) -> FabricConfig:
    return FabricConfig(
        horizon_ms=horizon_s * 1e3, policy="least-loaded",
        preemption=True, migrations=True, migration_period_ms=EPOCH_MS,
        autoscale=True, autoscale_mode=mode,
        autoscale_min_nodes=n_nodes, autoscale_max_nodes=4 * n_nodes,
        restore=RestoreCostModel.paper_default())


def _serve(scn, profs, cfg, horizon_s: float, seed: int,
           label: str | None = None) -> dict:
    t0 = time.perf_counter()
    fabric = build_fabric(scn, profs, cfg)
    trace = build_trace_soa(scn, profs, horizon_s, seed=seed)
    maybe_attach_timeline(trace)
    fm = fabric.serve_trace(trace)
    wall_s = time.perf_counter() - t0
    if label:
        maybe_dump_run(label, trace, fabric.nodes, cfg.horizon_ms,
                       migration_events=fm.migration_events)
    per_class = {}
    for level, pc in sorted(fm.fleet.per_class.items()):
        per_class[CLASS_NAMES.get(level, str(level))] = {
            "total": pc["total"],
            "violations": pc["violations"],
            "slo_attainment": 1.0 - pc["violations"] / max(pc["total"], 1),
        }
    fl = fm.fleet
    ok = fl.completed - (fl.slo_violations - fl.dropped)
    node_hours = (fm.node_seconds or 0.0) / 3600.0
    adds = [e for e in fm.scale_events if e.action == "add"]
    drains = [e for e in fm.scale_events if e.action == "drain"]
    return {
        "requests": fl.total,
        "completed": fl.completed,
        "dropped": fl.dropped,
        "conserved": fl.completed + fl.dropped == fl.total,
        "goodput_req_s": fm.goodput_req_s,
        "violation_rate": fm.violation_rate,
        "per_class": per_class,
        "node_hours": node_hours,
        "goodput_per_node_hour": ok / node_hours if node_hours else 0.0,
        "n_scale_up": len(adds),
        "n_scale_down": len(drains),
        "first_add_ms": min((e.t_ms for e in adds), default=None),
        "peak_nodes": max(
            (e.node_id + 1 for e in adds), default=None),
        "warmup_ms": [round(e.warmup_ms, 1) for e in adds],
        "scale_events": [
            [e.t_ms, e.action, e.node_id, e.t_ready_ms,
             round(e.warmup_ms, 1)] for e in fm.scale_events],
        "wall_s": wall_s,
    }


def run_point(n_nodes: int, horizon_s: float = HORIZON_S,
              seed: int = TRACE_SEED) -> dict:
    """Serve the same flash-crowd trace under both forecast arms."""
    profs, _intf, _ = setup()
    scn = _scenario(n_nodes, horizon_s)
    react = _serve(scn, profs, _cfg("reactive", n_nodes, horizon_s),
                   horizon_s, seed, label=f"autoscale_{n_nodes}n_reactive")
    pred = _serve(scn, profs, _cfg("predictive", n_nodes, horizon_s),
                  horizon_s, seed, label=f"autoscale_{n_nodes}n_predictive")
    return {
        "n_nodes": n_nodes,
        "horizon_s": horizon_s,
        "trace_seed": seed,
        "epoch_ms": EPOCH_MS,
        "reactive": react,
        "predictive": pred,
        "gold_attainment_delta":
            pred["per_class"]["gold"]["slo_attainment"]
            - react["per_class"]["gold"]["slo_attainment"],
        "goodput_per_node_hour_gain":
            pred["goodput_per_node_hour"]
            / max(react["goodput_per_node_hour"], 1e-9),
    }


def run(fast: bool = False) -> list[Row]:
    node_counts = (4,) if fast else NODE_COUNTS
    horizon_s = 20.0 if fast else HORIZON_S
    points = [run_point(n, horizon_s) for n in node_counts]
    if not fast:
        payload = {
            "benchmark": "autoscale_reactive_vs_predictive",
            "horizon_s": HORIZON_S,
            "trace_seed": TRACE_SEED,
            "epoch_ms": EPOCH_MS,
            "points": points,
        }
        merge_bench_json(OUT_PATH, "autoscale", payload)
    rows = []
    for p in points:
        b, r = p["reactive"], p["predictive"]
        rows.append(Row(
            f"fabric/autoscale_{p['n_nodes']}n",
            (b["wall_s"] + r["wall_s"]) * 1e6,
            f"requests={b['requests']} "
            f"gold_attain={100*b['per_class']['gold']['slo_attainment']:.2f}%"
            f"->{100*r['per_class']['gold']['slo_attainment']:.2f}% "
            f"goodput/nh={b['goodput_per_node_hour']:.0f}"
            f"->{r['goodput_per_node_hour']:.0f} "
            f"(x{p['goodput_per_node_hour_gain']:.2f}) "
            f"ups={b['n_scale_up']}/{r['n_scale_up']} "
            f"downs={b['n_scale_down']}/{r['n_scale_down']} "
            f"first_add={b['first_add_ms']}/{r['first_add_ms']}ms"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3-node CI smoke: conservation + predictive win")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)
    if not args.tiny:
        for row in run():
            print(row.csv())
        return 0
    p = run_point(3, horizon_s=20.0)
    b, r = p["reactive"], p["predictive"]
    print(f"autoscale-tiny n=3 requests={b['requests']} "
          f"gold {100*b['per_class']['gold']['slo_attainment']:.2f}%->"
          f"{100*r['per_class']['gold']['slo_attainment']:.2f}% "
          f"goodput/nh {b['goodput_per_node_hour']:.0f}->"
          f"{r['goodput_per_node_hour']:.0f} "
          f"ups={b['n_scale_up']}/{r['n_scale_up']} "
          f"downs={b['n_scale_down']}/{r['n_scale_down']}")
    if not (b["conserved"] and r["conserved"]):
        print("SMOKE FAIL: request conservation broken across scale cuts")
        return 1
    if not (b["n_scale_up"] and r["n_scale_up"]):
        print("SMOKE FAIL: the flash crowd never triggered a scale-up")
        return 1
    if p["gold_attainment_delta"] < 0:
        print("SMOKE FAIL: predictive lost gold-class SLO attainment "
              "to reactive")
        return 1
    if p["goodput_per_node_hour_gain"] < 1.0:
        print("SMOKE FAIL: predictive lost goodput-per-node-hour "
              "to reactive")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
