"""Beyond-paper: the gpu-let scheduler over TPU pod sub-meshes (tpu-lets).

Schedules a mix of the assigned architectures onto pods using L(b, p) tables
derived from the compiled dry-run (core/tpulets.py), and compares elastic
partitioning against no-partitioning (SBP, whole pods only) — the paper's
headline experiment transplanted to TPU.
"""
from __future__ import annotations

import os

from benchmarks.common import Row, timed
from repro.core import ElasticPartitioning, SquishyBinPacking
from repro.core.hardware import AcceleratorSpec, ClusterSpec

RESULTS = "results/dryrun.jsonl"
MIX = {"yi-9b": 1.0, "chatglm3-6b": 1.0, "mamba2-780m": 4.0,
       "deepseek-moe-16b": 1.0, "recurrentgemma-2b": 2.0}


def run(fast: bool = False) -> list[Row]:
    if not os.path.exists(RESULTS):
        return [Row("tpulet/missing", 0.0, f"needs {RESULTS} (dry-run)")]
    from repro.core.tpulets import load_catalog
    profiles, provider = load_catalog(RESULTS)
    mix = {m: r for m, r in MIX.items() if m in profiles}
    if not mix:
        return [Row("tpulet/missing", 0.0, "no decode records yet")]
    pod = AcceleratorSpec(name="v5e-pod", peak_tflops=197.0 * 256,
                          hbm_gbs=819.0 * 256, hbm_gb=16.0 * 256,
                          ici_gbs=50.0)
    cluster = ClusterSpec(accelerator=pod, n_devices=4)
    rows = []
    results = {}
    for name, sched in (
        ("sbp_whole_pods", SquishyBinPacking(
            mix and {m: profiles[m] for m in mix}, cluster=cluster,
            lat=provider)),
        ("gpulet_tpulets", ElasticPartitioning(
            {m: profiles[m] for m in mix}, cluster=cluster, lat=provider)),
    ):
        lam, us = timed(sched.max_scale, mix, 0.0, 1 << 16)
        total = lam * sum(mix.values())
        results[name] = total
        rows.append(Row(f"tpulet/{name}", us,
                        f"max_rate={total:.0f} req/s over 4 pods "
                        f"({len(mix)} models)"))
    if results.get("sbp_whole_pods"):
        gain = results["gpulet_tpulets"] / results["sbp_whole_pods"] - 1
        rows.append(Row("tpulet/gain", 0.0,
                        f"elastic_vs_whole_pods=+{100*gain:.1f}% "
                        f"(paper on GPUs: +102.6%)"))
    elif results.get("gpulet_tpulets"):
        rows.append(Row("tpulet/gain", 0.0,
                        "whole-pod SBP cannot co-schedule the SLO-"
                        "heterogeneous mix at ANY rate (duty cycle cannot "
                        "fit 5 models); tpu-let partitioning admits it — "
                        "the paper's Fig. 4 schedulability result on TPU"))
    return rows
