"""Beyond-paper: the gpu-let scheduler over TPU pod sub-meshes (tpu-lets).

Two parts:

  1. Scheduling comparison — elastic partitioning vs no-partitioning (SBP,
     whole pods only) on max sustainable rate, the paper's headline
     experiment transplanted to TPU (L(b, p) derived from the compiled
     dry-run's roofline terms, core/tpulets.py).
  2. End-to-end serving — the ROADMAP open item: the same tpu-let schedule
     *executed* by the event-heap engine (pluggable latency provider,
     interference off — sub-meshes are disjoint), a Poisson trace, SLO
     accounting.

Prefers real dry-run terms (results/dryrun.jsonl); containers that never
ran the compiled dry-run fall back to the labeled synthetic catalog so the
path still runs end to end.

CLI: ``python -m benchmarks.tpulet_serving --smoke`` runs the tiny CI
configuration and exits non-zero on conservation/SLO blow-ups.
"""
from __future__ import annotations

import os

from benchmarks.common import Row, timed
from repro.core import ElasticPartitioning, SquishyBinPacking
from repro.core.hardware import AcceleratorSpec, ClusterSpec

RESULTS = "results/dryrun.jsonl"
MIX = {"yi-9b": 1.0, "chatglm3-6b": 1.0, "mamba2-780m": 4.0,
       "deepseek-moe-16b": 1.0, "recurrentgemma-2b": 2.0}
SYNTH_MIX = {"kv-bound-9b": 1.0, "weight-bound-2b": 2.0, "moe-16b": 1.0}

#: one scheduling "device" = one v5e pod slice
POD = AcceleratorSpec(name="v5e-pod", peak_tflops=197.0 * 256,
                      hbm_gbs=819.0 * 256, hbm_gb=16.0 * 256, ici_gbs=50.0)


def _catalog():
    """(profiles, provider, mix, source) — dry-run terms or synthetic."""
    if os.path.exists(RESULTS):
        from repro.core.tpulets import load_catalog
        profiles, provider = load_catalog(RESULTS)
        mix = {m: r for m, r in MIX.items() if m in profiles}
        if mix:
            return profiles, provider, mix, "dryrun"
    from repro.core.tpulets import synthetic_catalog
    profiles, provider = synthetic_catalog()
    return profiles, provider, dict(SYNTH_MIX), "synthetic"


def serve_end_to_end(profiles, provider, rates, horizon_s: float = 20.0,
                     n_pods: int = 4, seed: int = 0):
    """Run a tpu-let schedule through the event engine; returns metrics."""
    from repro.simulator import EngineConfig, EventHeapEngine, PoissonArrivals
    from repro.simulator.events import merge_sorted
    cluster = ClusterSpec(accelerator=POD, n_devices=n_pods)
    sched = ElasticPartitioning(profiles, cluster=cluster, lat=provider)
    result = sched.schedule(rates)
    horizon_ms = horizon_s * 1e3
    gen = PoissonArrivals(seed=seed)
    reqs = merge_sorted([
        gen.constant(m, r, profiles[m].slo_ms, horizon_ms)
        for m, r in rates.items()])
    eng = EventHeapEngine(
        profiles,
        EngineConfig(horizon_ms=horizon_ms, acc=POD, lat=provider,
                     interference=False),
        schedule=result)
    eng.submit(reqs)
    return eng.run(), result


def run(fast: bool = False) -> list[Row]:
    profiles, provider, mix, source = _catalog()
    cluster = ClusterSpec(accelerator=POD, n_devices=4)
    rows = [Row("tpulet/catalog", 0.0,
                f"source={source} archs={len(profiles)}")]
    results = {}
    for name, sched in (
        ("sbp_whole_pods", SquishyBinPacking(
            {m: profiles[m] for m in mix}, cluster=cluster, lat=provider)),
        ("gpulet_tpulets", ElasticPartitioning(
            {m: profiles[m] for m in mix}, cluster=cluster, lat=provider)),
    ):
        lam, us = timed(sched.max_scale, mix, 0.0, 1 << 16)
        total = lam * sum(mix.values())
        results[name] = total
        rows.append(Row(f"tpulet/{name}", us,
                        f"max_rate={total:.0f} req/s over 4 pods "
                        f"({len(mix)} models)"))
    if results.get("sbp_whole_pods"):
        gain = results["gpulet_tpulets"] / results["sbp_whole_pods"] - 1
        rows.append(Row("tpulet/gain", 0.0,
                        f"elastic_vs_whole_pods=+{100*gain:.1f}% "
                        f"(paper on GPUs: +102.6%)"))
    elif results.get("gpulet_tpulets"):
        rows.append(Row("tpulet/gain", 0.0,
                        "whole-pod SBP cannot co-schedule the SLO-"
                        "heterogeneous mix at ANY rate (duty cycle cannot "
                        "fit 5 models); tpu-let partitioning admits it — "
                        "the paper's Fig. 4 schedulability result on TPU"))
    # end-to-end: serve at 60% of the elastic max through the event engine
    lam60 = 0.6 * results["gpulet_tpulets"] / sum(mix.values())
    rates = {m: r * lam60 for m, r in mix.items()}
    horizon_s = 5.0 if fast else 20.0
    (met, sresult), us = timed(serve_end_to_end, profiles, provider, rates,
                               horizon_s=horizon_s)
    rows.append(Row(
        "tpulet/serve_end_to_end", us,
        f"requests={met.total} completed={met.completed} "
        f"violations={100*met.violation_rate:.2f}% "
        f"goodput={met.goodput_req_s:.0f}req/s "
        f"tpulets={sum(1 for l in sresult.gpulets if not l.is_free)} "
        f"horizon={horizon_s:.0f}s"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; non-zero exit on basic invariants")
    args = ap.parse_args()
    if not args.smoke:
        for row in run():
            print(row.csv())
        return 0
    # smoke: gate on the metrics object itself, not on parsing our own
    # formatted rows (a cosmetic rename must not disable the check).
    profiles, provider, mix, source = _catalog()
    cluster = ClusterSpec(accelerator=POD, n_devices=4)
    sched = ElasticPartitioning(
        {m: profiles[m] for m in mix}, cluster=cluster, lat=provider)
    lam = sched.max_scale(mix, 0.0, 1 << 16)
    if lam <= 0.0:
        print(f"SMOKE FAIL: elastic tpu-let scheduler admits no load "
              f"(source={source})")
        return 1
    rates = {m: r * 0.6 * lam for m, r in mix.items()}
    met, _ = serve_end_to_end(profiles, provider, rates, horizon_s=5.0)
    print(f"tpulet-smoke source={source} requests={met.total} "
          f"violations={100*met.violation_rate:.2f}% "
          f"goodput={met.goodput_req_s:.0f}req/s")
    if met.total == 0 or met.completed + met.dropped != met.total:
        print("SMOKE FAIL: request conservation broken")
        return 1
    if met.violation_rate > 0.20:
        print(f"SMOKE FAIL: {100*met.violation_rate:.1f}% violations "
              f"at 60% load")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
