"""Kernel micro-benchmarks: pure-jnp reference path timings on CPU.

(The Pallas kernels target TPU; interpret mode is a correctness harness, not
a performance path, so us_per_call here times the jnp reference the dry-run
lowers.  Derived fields record interpret-mode max error vs. the oracle.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(fast: bool = False) -> list[Row]:
    key = jax.random.key(0)
    rows = []

    # flash attention
    b, h, hkv, s, dh = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (b, h, s, dh), jnp.float32)
    k = jax.random.normal(key, (b, hkv, s, dh), jnp.float32)
    v = jax.random.normal(key, (b, hkv, s, dh), jnp.float32)
    jit_ref = jax.jit(lambda *a: ref.flash_attention_ref(*a, causal=True))
    us = _time(jit_ref, q, k, v)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(jit_ref(q, k, v)))))
    rows.append(Row("kernels/flash_attention", us,
                    f"shape=b{b}h{h}s{s}d{dh} interpret_err={err:.2e}"))

    # decode attention
    q1 = jax.random.normal(key, (2, 8, 64), jnp.float32)
    kc = jax.random.normal(key, (2, 1024, 2, 64), jnp.float32)
    vc = jax.random.normal(key, (2, 1024, 2, 64), jnp.float32)
    lens = jnp.array([700, 1000], jnp.int32)
    jit_ref2 = jax.jit(lambda *a: ref.decode_attention_ref(*a))
    us = _time(jit_ref2, q1, kc, vc, lens)
    out = decode_attention(q1, kc, vc, lens, interpret=True)
    err = float(np.max(np.abs(np.asarray(out)
                              - np.asarray(jit_ref2(q1, kc, vc, lens)))))
    rows.append(Row("kernels/decode_attention", us,
                    f"cache=1024x2x64 interpret_err={err:.2e}"))

    # ssd scan
    xh = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 4)))
    a = -jnp.exp(jax.random.normal(key, (4,)))
    bm = jax.random.normal(key, (1, 512, 64), jnp.float32) * 0.3
    cm = jax.random.normal(key, (1, 512, 64), jnp.float32) * 0.3
    jit_ref3 = jax.jit(lambda *args: ref.ssd_scan_ref(*args)[0])
    us = _time(jit_ref3, xh, dt, a, bm, cm)
    out = ssd_scan(xh, dt, a, bm, cm, chunk=128, interpret=True)
    err = float(np.max(np.abs(np.asarray(out)
                              - np.asarray(jit_ref3(xh, dt, a, bm, cm)))))
    rows.append(Row("kernels/ssd_scan", us,
                    f"s512h4p64n64 interpret_err={err:.2e}"))

    # rglru scan
    ag = jax.nn.sigmoid(jax.random.normal(key, (2, 512, 256))) * 0.2 + 0.8
    bg = jax.random.normal(key, (2, 512, 256)) * 0.1
    jit_ref4 = jax.jit(lambda *args: ref.rglru_scan_ref(*args)[0])
    us = _time(jit_ref4, ag, bg)
    out = rglru_scan(ag, bg, block_t=128, interpret=True)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(jit_ref4(ag, bg)))))
    rows.append(Row("kernels/rglru_scan", us,
                    f"s512w256 interpret_err={err:.2e}"))
    return rows
