"""Fig. 9: linear interference-model prediction error CDF.

Paper: 90% of cases within 10.26% error, 95% within 13.98%.
"""
from __future__ import annotations

from benchmarks.common import Row, setup, timed
from repro.core import fit_default_model


def run(fast: bool = False) -> list[Row]:
    profs, _, _ = setup()
    (_, stats), us = timed(fit_default_model, profs)
    return [Row("fig09/intf_model_error", us,
                f"train={stats['n_train']} val={stats['n_val']} "
                f"p90_err={stats['p90_rel_err']:.4f} (paper 0.1026) "
                f"p95_err={stats['p95_rel_err']:.4f} (paper 0.1398) "
                f"mean={stats['mean_rel_err']:.4f}")]
