"""Fig. 4: schedulable scenarios (of 1023) without vs. with GPU partitioning."""
from __future__ import annotations

from benchmarks.common import Row, setup, timed
from repro.core import ElasticPartitioning, SquishyBinPacking
from repro.core.hardware import ClusterSpec, RTX_2080TI
from repro.core.scenarios import schedulability_population


def run_tiny() -> list[Row]:
    """CI smoke: 1-GPU, 2-model schedulability sweep (seconds, not minutes).

    Exercises the full admission path (duty-cycle search, EDF offsets,
    best-fit splitting) on a deliberately tiny configuration so admission
    regressions surface in CI without the cost of the 1023-scenario sweep.
    The invariant checked: partitioning never *loses* scenarios — elastic
    must admit at least as many of the population as unpartitioned SBP.
    """
    profs, intf, _ = setup()
    cluster = ClusterSpec(accelerator=RTX_2080TI, n_devices=1)
    pop = schedulability_population(models=("goo", "res"))
    rows = []
    counts = {}
    for name, sched in (
        ("sbp_no_partition", SquishyBinPacking(profs, cluster=cluster)),
        ("gpulet", ElasticPartitioning(profs, cluster=cluster)),
        ("gpulet+int", ElasticPartitioning(profs, cluster=cluster,
                                           intf_model=intf)),
    ):
        count, us = timed(
            lambda s=sched: sum(1 for r in pop if s.is_schedulable(r)))
        counts[name] = count
        rows.append(Row(f"fig04tiny/{name}", us,
                        f"schedulable={count}/{len(pop)}"))
    assert 0 < counts["gpulet"] <= len(pop), counts
    assert counts["gpulet"] >= counts["sbp_no_partition"], counts
    return rows


def run(fast: bool = False) -> list[Row]:
    profs, _, _ = setup()
    pop = schedulability_population()
    if fast:
        pop = pop[::8]
    rows = []
    for name, sched in (
        ("sbp_no_partition", SquishyBinPacking(profs)),
        ("sbp_even_split", SquishyBinPacking(profs, split_even=True)),
    ):
        count, us = timed(
            lambda s=sched: sum(1 for r in pop if s.is_schedulable(r)))
        rows.append(Row(f"fig04/{name}", us,
                        f"schedulable={count}/{len(pop)}"))
    return rows


if __name__ == "__main__":
    import sys

    tiny = "--tiny" in sys.argv
    print("name,us_per_call,derived")
    for row in (run_tiny() if tiny else run(fast="--fast" in sys.argv)):
        print(row.csv())
