"""Fig. 4: schedulable scenarios (of 1023) without vs. with GPU partitioning."""
from __future__ import annotations

from benchmarks.common import Row, setup, timed
from repro.core import SquishyBinPacking
from repro.core.scenarios import schedulability_population


def run(fast: bool = False) -> list[Row]:
    profs, _, _ = setup()
    pop = schedulability_population()
    if fast:
        pop = pop[::8]
    rows = []
    for name, sched in (
        ("sbp_no_partition", SquishyBinPacking(profs)),
        ("sbp_even_split", SquishyBinPacking(profs, split_even=True)),
    ):
        count, us = timed(
            lambda s=sched: sum(1 for r in pop if s.is_schedulable(r)))
        rows.append(Row(f"fig04/{name}", us,
                        f"schedulable={count}/{len(pop)}"))
    return rows
