"""Beyond-paper ablations: what each scheduler ingredient buys.

1. headroom sweep — burst margin vs claimed throughput vs violations;
2. the >85%-utilization partition bump (EXPERIMENTS.md §Paper-validation);
3. prospective-interference slack (the gpulet+int conservatism mechanism).
"""
from __future__ import annotations

from benchmarks.common import Row, setup, timed
from repro.core import ElasticPartitioning
from repro.core.scenarios import REQUEST_SCENARIOS
from repro.simulator import PoissonArrivals, SimConfig, simulate_schedule
from repro.simulator.events import merge_sorted


def _measure(sched, profs, rates, horizon=12_000.0):
    lam = sched.max_scale(rates)
    use = {m: r * lam * 0.999 for m, r in rates.items() if r > 0}
    res = sched.schedule(use)
    gen = PoissonArrivals(seed=9)
    reqs = merge_sorted([gen.constant(m, r, profs[m].slo_ms, horizon)
                         for m, r in use.items()])
    met = simulate_schedule(res, profs, reqs, SimConfig(horizon_ms=horizon))
    return sum(use.values()), met.violation_rate


def run(fast: bool = False) -> list[Row]:
    profs, intf, _ = setup()
    rates = REQUEST_SCENARIOS["equal"]
    rows = []
    for headroom in ((0.9, 0.8, 0.7) if not fast else (0.8,)):
        sched = ElasticPartitioning(profs, intf_model=intf,
                                    headroom=headroom)
        (rate, viol), us = timed(_measure, sched, profs, rates)
        rows.append(Row(f"ablation/headroom={headroom}", us,
                        f"claimed={rate:.0f}/s violations={100*viol:.2f}% "
                        f"(burst margin vs throughput trade)"))
    # prospective slack off = plain gpulet (already in fig12/13); here the
    # marginal effect of interference *revalidation* alone:
    sched = ElasticPartitioning(profs, intf_model=intf)
    (rate, viol), us = timed(_measure, sched, profs, rates)
    rows.append(Row("ablation/gpulet+int_reference", us,
                    f"claimed={rate:.0f}/s violations={100*viol:.2f}%"))
    return rows
