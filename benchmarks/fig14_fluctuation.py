"""Fig. 14: adapting to fluctuating request rates over a long window.

Paper: partitions track two load waves over 1800 s; violations total 0.14%.
"""
from __future__ import annotations

import math

from benchmarks.common import Row, setup, timed
from repro.core import ElasticPartitioning
from repro.serving import ServingController


def run(fast: bool = False) -> list[Row]:
    profs, intf, _ = setup()
    sched = ElasticPartitioning(profs, intf_model=intf)
    ctrl = ServingController(sched, profs, seed=7)
    base = {"le": 100, "goo": 60, "res": 40, "ssd": 30, "vgg": 25}

    def mk(m, phase):
        def fn(t):
            w1 = math.exp(-((t - 300) / 120) ** 2) * 1.2
            w2 = math.exp(-((t - 1050) / 150) ** 2) * 2.0
            return base[m] * (0.5 + w1 + w2 + 0.1 * math.sin(t / 37 + phase))
        return fn

    fns = {m: mk(m, i) for i, m in enumerate(base)}
    horizon = 400.0 if fast else 1800.0
    recs, us = timed(ctrl.run, fns, horizon)
    tot = sum(r.metrics.total for r in recs)
    viol = sum(r.metrics.slo_violations for r in recs)
    peak = max(r.used_partition_total for r in recs)
    trough = min(r.used_partition_total for r in recs)
    return [Row("fig14/fluctuation", us,
                f"periods={len(recs)} requests={tot} "
                f"violations={100*viol/max(tot,1):.3f}% (paper 0.14%) "
                f"rescheds={sum(r.rescheduled for r in recs)} "
                f"midflight_reorgs={ctrl.engine.epoch - 1} "
                f"partition_range={trough}%..{peak}% (adapts)")]
