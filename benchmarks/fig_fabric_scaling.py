"""Fabric scaling: goodput and per-class SLO violations from 1 to 16 nodes.

Beyond-paper (ROADMAP "cluster of clusters"): a weak-scaling sweep of the
multi-node serving fabric — each node is a 4-GPU paper cluster provisioned
for ~500 req/s of the mixed paper workload; the fleet rate grows with the
node count.  Traffic is tiered 20% gold / 50% silver / 30% bronze and
nodes run with preemption enabled; the router pays a 0.15 ms one-way RPC
delay per dispatch.  Perfect scaling = flat per-node goodput and flat
violation rates; the gap is the fabric's dispatch + network overhead.

Emits machine-readable ``BENCH_fabric.json`` at the repo root (benchmark
trajectory tracking) in addition to the usual CSV rows.

CLI: ``python -m benchmarks.fig_fabric_scaling --tiny`` runs the 2-node,
2-model CI smoke and exits non-zero on conservation or scaling blow-ups.
"""
from __future__ import annotations

import dataclasses
import os
import time

from benchmarks.common import (Row, add_trace_dir_arg, maybe_attach_timeline,
                               maybe_dump_run, merge_bench_json,
                               set_trace_dir, setup, trace_dir)
from repro.core.scenarios import fabric_node_sweep
from repro.fabric import (FabricConfig, NetworkModel, build_fabric,
                          build_trace_soa)
from repro.fabric.priority import CLASS_NAMES

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fabric.json")

#: sweep horizon: 16 nodes x ~500 req/s x 65 s ~= 520k fleet requests
HORIZON_S = 65.0
NODE_COUNTS = (1, 2, 4, 8, 16)


def run_sweep(node_counts=NODE_COUNTS, horizon_s=HORIZON_S,
              per_node_rates=None, seed: int = 0) -> list[dict]:
    profs, _intf, _ = setup()
    out = []
    for scn in fabric_node_sweep(per_node_rates=per_node_rates,
                                 node_counts=node_counts):
        # the SoA hot path end to end: trace generated straight into
        # arrays, index-slice dispatch, engines across forked workers,
        # no per-event log
        cfg = FabricConfig(horizon_ms=horizon_s * 1e3,
                           policy="least-loaded",
                           network=NetworkModel(base_ms=0.15, seed=seed),
                           preemption=True,
                           node_workers=os.cpu_count() or 1)
        t0 = time.perf_counter()
        fabric = build_fabric(scn, profs, cfg)
        for node in fabric.nodes:
            # span records stay off on the hot path unless --trace-dir
            # asked for a Perfetto export of this run
            node.cfg = dataclasses.replace(node.cfg,
                                           event_log=trace_dir() is not None)
        trace = build_trace_soa(scn, profs, horizon_s, seed=seed)
        maybe_attach_timeline(trace)
        fm = fabric.serve_trace(trace)
        wall_s = time.perf_counter() - t0
        maybe_dump_run(f"fabric_scaling_{scn.n_nodes}n", trace,
                       fabric.nodes, horizon_s * 1e3,
                       migration_events=fm.migration_events)
        per_class = {}
        for level, pc in sorted(fm.fleet.per_class.items()):
            per_class[CLASS_NAMES.get(level, str(level))] = {
                "total": pc["total"],
                "violations": pc["violations"],
                "violation_rate": pc["violations"] / max(pc["total"], 1),
                "dropped": pc["dropped"],
                "preempted": pc["preempted"],
            }
        out.append({
            "n_nodes": scn.n_nodes,
            "requests": len(trace),
            "completed": fm.fleet.completed,
            "dropped": fm.fleet.dropped,
            "goodput_req_s": fm.goodput_req_s,
            "goodput_per_node_req_s": fm.goodput_req_s / scn.n_nodes,
            "violation_rate": fm.violation_rate,
            "latency_ms_per_model": fm.fleet.latency_ms_per_model,
            "per_class": per_class,
            "preemptions": fm.preemptions,
            "shed": {str(k): v for k, v in fm.stats.shed.items()},
            "rerouted": {str(k): v for k, v in fm.stats.rerouted.items()},
            "rerouted_total": fm.rerouted_total(),
            "handed_back": fm.handed_back,
            "failed_over": fm.failed_over,
            "lost": fm.lost_total(),
            "wall_s": wall_s,
        })
    return out


def run(fast: bool = False) -> list[Row]:
    node_counts = (1, 2) if fast else NODE_COUNTS
    horizon_s = 10.0 if fast else HORIZON_S
    sweep = run_sweep(node_counts=node_counts, horizon_s=horizon_s)
    if not fast:
        # only the full sweep refreshes the trajectory artifact — the
        # shrunken --fast config would clobber it with incomparable
        # numbers under the same keys.
        payload = {"benchmark": "fabric_scaling", "horizon_s": horizon_s,
                   "policy": "least-loaded", "preemption": True,
                   "sweep": sweep}
        merge_bench_json(OUT_PATH, "fabric_scaling", payload)
    rows = []
    for s in sweep:
        cls = " ".join(
            f"{name}={100*d['violation_rate']:.2f}%"
            for name, d in s["per_class"].items())
        rows.append(Row(
            f"fabric/scaling_{s['n_nodes']}n", s["wall_s"] * 1e6,
            f"requests={s['requests']} "
            f"goodput={s['goodput_req_s']:.0f}req/s "
            f"per_node={s['goodput_per_node_req_s']:.0f}req/s "
            f"viol={100*s['violation_rate']:.2f}% [{cls}] "
            f"preempts={s['preemptions']}"))
    base = sweep[0]["goodput_per_node_req_s"]
    top = sweep[-1]
    eff = top["goodput_per_node_req_s"] / base if base else 0.0
    rows.append(Row(
        "fabric/scaling_efficiency", 0.0,
        f"{sweep[0]['n_nodes']}n->{top['n_nodes']}n "
        f"per-node goodput retention={100*eff:.1f}% "
        f"(json={os.path.basename(OUT_PATH)})"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="2-node 2-model CI smoke")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)
    if not args.tiny:
        for row in run():
            print(row.csv())
        return 0
    sweep = run_sweep(node_counts=(1, 2), horizon_s=8.0,
                      per_node_rates={"goo": 80.0, "res": 60.0})
    for s in sweep:
        print(f"fabric-tiny n={s['n_nodes']} requests={s['requests']} "
              f"viol={100*s['violation_rate']:.2f}% "
              f"conserved={s['completed'] + s['dropped'] == s['requests']}")
    for s in sweep:
        if s["completed"] + s["dropped"] != s["requests"]:
            print("SMOKE FAIL: request conservation broken")
            return 1
        if s["violation_rate"] > 0.10:
            print(f"SMOKE FAIL: {100*s['violation_rate']:.1f}% violations "
                  f"at provisioned load on {s['n_nodes']} node(s)")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
