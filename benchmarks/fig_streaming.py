"""Streaming serving: TTFT attainment, phase-aware vs oblivious (ISSUE 7).

Beyond-paper (ROADMAP "token-level streaming serving"): requests are
prefill/decode streams with per-phase SLOs — a TTFT deadline on the
prefill and a TPOT cadence on every decode token — served by the node
engines' continuous-batching walk.  The same seeded Zipf trace is served
twice on the same fleet shape:

  * **aware** — phase-aware placement: each model's booked rate is
    inflated by its stream occupancy (amortized prefill + the decode
    tail at the concurrency it can actually sustain), so the
    partitioner provisions gpu-lets for the decode work too; the router
    weights its fluid backlog by the same factors.
  * **oblivious** — streams booked as one opaque L(b, p) launch each
    (raw rates, unweighted router): the decode tail steals duty-cycle
    time nobody provisioned, and prefills queue behind it.

Reports TTFT attainment, TTFT/TPOT percentiles, and token completion;
the acceptance bar is aware beating oblivious on TTFT attainment at the
8-node rung.  Results merge into ``BENCH_fabric.json`` under
``"streaming"``.

CLI: ``python -m benchmarks.fig_streaming --tiny`` runs a 3-node CI
smoke and exits non-zero on conservation breaks, token-accounting
breaks, a TTFT-attainment floor miss, or aware losing to oblivious.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import (Row, add_trace_dir_arg, maybe_attach_timeline,
                               maybe_dump_run, merge_bench_json,
                               set_trace_dir, setup)
from repro.core.scenarios import streaming_zipf_scenario
from repro.fabric import FabricConfig
from repro.fabric.workload import (build_stream_fabric,
                                   build_stream_trace_soa,
                                   stream_occupancies)
from repro.simulator import collect_streams
from repro.simulator.trace import PENDING

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fabric.json")

#: the operating point: offered prefill load at 1.6 sweep-mix units per
#: node (still fully schedulable in both arms — no unplaced rates, no
#: unserved streams).  ``util`` counts only what a phase-oblivious
#: provisioner sees, so the decode tail is the unprovisioned surprise;
#: at comfortable load the slack hides it, at 1.6 it decides TTFT.
UTIL = 1.6
HORIZON_S = 12.0
NODE_COUNTS = (4, 8)
SEED = 7

#: CI smoke bar: the 3-node tiny rung must keep at least this fraction
#: of streams inside their TTFT SLO with phase-aware placement
TINY_ATTAINMENT_FLOOR = 0.90


def _serve(scn, profs, aware: bool, horizon_s: float, seed: int,
           label: str | None = None) -> dict:
    t0 = time.perf_counter()
    trace = build_stream_trace_soa(scn, profs, horizon_s, seed=seed)
    maybe_attach_timeline(trace)
    fabric = build_stream_fabric(
        scn, profs, cfg=FabricConfig(horizon_ms=horizon_s * 1e3),
        phase_aware=aware)
    fm = fabric.serve_trace(trace)
    sm = collect_streams(trace)
    wall_s = time.perf_counter() - t0
    if label:
        maybe_dump_run(label, trace, fabric.nodes, horizon_s * 1e3)
    f = fm.fleet
    return {
        "streams": sm.streams,
        "completed": sm.completed,
        "conserved": not bool((trace.status == PENDING).any()),
        "tokens_ok": bool((trace.tokens_done <= trace.output_len).all()),
        "ttft_attainment": sm.ttft_attainment,
        "token_completion": sm.token_completion,
        "ttft_p50_ms": sm.ttft_ms["p50"],
        "ttft_p99_ms": sm.ttft_ms["p99"],
        "tpot_p50_ms": sm.tpot_ms["p50"],
        "tpot_p99_ms": sm.tpot_ms["p99"],
        "e2e_violation_rate": f.violation_rate,
        "per_model_ttft_attainment": {
            m: g["ttft_attainment"] for m, g in sm.per_model.items()},
        "wall_s": wall_s,
    }


def run_point(n_nodes: int, horizon_s: float = HORIZON_S,
              seed: int = SEED) -> dict:
    """Serve the same streaming trace with and without phase awareness."""
    profs, _intf, _ = setup()
    scn = streaming_zipf_scenario(n_nodes, util=UTIL)
    aware = _serve(scn, profs, True, horizon_s, seed,
                   label=f"streaming_{n_nodes}n_phase_aware")
    obliv = _serve(scn, profs, False, horizon_s, seed,
                   label=f"streaming_{n_nodes}n_oblivious")
    return {
        "n_nodes": n_nodes,
        "horizon_s": horizon_s,
        "occupancy": {m: round(v, 3) for m, v in
                      stream_occupancies(scn, profs).items()},
        "aware": aware,
        "oblivious": obliv,
        "ttft_attainment_delta":
            aware["ttft_attainment"] - obliv["ttft_attainment"],
    }


def run(fast: bool = False) -> list[Row]:
    node_counts = (4,) if fast else NODE_COUNTS
    horizon_s = 6.0 if fast else HORIZON_S
    points = [run_point(n, horizon_s) for n in node_counts]
    if not fast:
        payload = {
            "benchmark": "streaming_aware_vs_oblivious",
            "util": UTIL,
            "horizon_s": HORIZON_S,
            "points": points,
        }
        merge_bench_json(OUT_PATH, "streaming", payload)
    rows = []
    for p in points:
        a, o = p["aware"], p["oblivious"]
        rows.append(Row(
            f"fabric/streaming_{p['n_nodes']}n",
            (a["wall_s"] + o["wall_s"]) * 1e6,
            f"streams={a['streams']} "
            f"ttft={100*o['ttft_attainment']:.2f}%"
            f"->{100*a['ttft_attainment']:.2f}% "
            f"(+{100*p['ttft_attainment_delta']:.2f}pt) "
            f"ttft_p99={o['ttft_p99_ms']:.1f}"
            f"->{a['ttft_p99_ms']:.1f}ms "
            f"tok={100*a['token_completion']:.2f}%"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3-node CI smoke: conservation + TTFT bars")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)
    if not args.tiny:
        for row in run():
            print(row.csv())
        return 0
    p = run_point(3, horizon_s=8.0)
    a, o = p["aware"], p["oblivious"]
    print(f"streaming-tiny n=3 streams={a['streams']} "
          f"ttft {100*o['ttft_attainment']:.2f}%->"
          f"{100*a['ttft_attainment']:.2f}% "
          f"ttft_p99 {a['ttft_p99_ms']:.1f}ms "
          f"tpot_p99 {a['tpot_p99_ms']:.1f}ms")
    if not (a["conserved"] and o["conserved"]):
        print("SMOKE FAIL: stream conservation broken")
        return 1
    if not (a["tokens_ok"] and o["tokens_ok"]):
        print("SMOKE FAIL: token accounting exceeded output_len")
        return 1
    if a["streams"] == 0:
        print("SMOKE FAIL: the scenario generated no streams")
        return 1
    if a["ttft_attainment"] < TINY_ATTAINMENT_FLOOR:
        print(f"SMOKE FAIL: aware TTFT attainment "
              f"{a['ttft_attainment']:.3f} below the "
              f"{TINY_ATTAINMENT_FLOOR} floor")
        return 1
    if a["ttft_attainment"] < o["ttft_attainment"]:
        print("SMOKE FAIL: phase-aware placement lost TTFT attainment "
              "to phase-oblivious booking")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
