"""Compound inference: job-SLO attainment, DAG-aware vs oblivious (ISSUE 6).

Beyond-paper (ROADMAP "requests as model DAGs"): client requests are
task graphs over several models — a chain (frontend -> detector ->
classifier) and a fan-out/fan-in (frontend -> detector -> 3 parallel
per-region classifiers -> fusion) — mixed with classic single-model
traffic on one fleet.  Every job carries ONE end-to-end SLO, decomposed
into per-stage budgets along the critical path; the fabric's release
frontier dispatches each stage at ``max(parent completions)``.  The same
seeded trace is served twice:

  * **aware** — critical-path-aware dispatch: 1:1 parent->child edges
    co-locate on the parent's node (no RPC round trip), fan-in joins
    follow the latest-finishing parent, parallel branches spread.
  * **oblivious** — stages routed like unrelated single requests
    (``dag_colocation=False``): every hop pays the network delay.

Reports end-to-end job-SLO attainment and job latency percentiles; the
acceptance bar is aware beating oblivious on attainment at the 8-node
rung.  Results merge into ``BENCH_fabric.json`` under ``"dag"``.

CLI: ``python -m benchmarks.fig_dag --tiny`` runs a 3-node CI smoke and
exits non-zero on conservation breaks, a job-attainment floor miss, or
aware losing to oblivious.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import (Row, add_trace_dir_arg, maybe_attach_timeline,
                               maybe_dump_run, merge_bench_json,
                               set_trace_dir, setup)
from repro.core.scenarios import mixed_dag_scenario
from repro.fabric import FabricConfig
from repro.fabric.network import NetworkModel
from repro.fabric.workload import build_dag_fabric, build_dag_trace_soa

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fabric.json")

#: the operating point: end-to-end SLOs at 2x the critical-path sum of
#: the stage models' standalone SLOs (scale 1.0 leaves zero headroom for
#: queueing or frontier staleness), a 3 ms one-way RPC per off-node hop
#: (what co-location dodges), and background singles at 40% of the sweep
#: mix so DAG jobs compete with ordinary traffic
SLO_SCALE = 2.0
NET_BASE_MS = 3.0
HORIZON_S = 12.0
NODE_COUNTS = (4, 8)
SEED = 7

#: CI smoke bar: the 3-node tiny rung must keep at least this fraction
#: of jobs inside their end-to-end SLO with aware dispatch
TINY_ATTAINMENT_FLOOR = 0.70


def _cfg(colocation: bool) -> FabricConfig:
    return FabricConfig(policy="least-loaded", preemption=True,
                        network=NetworkModel(base_ms=NET_BASE_MS),
                        dag_colocation=colocation)


def _serve(scn, profs, colocation: bool, horizon_s: float,
           seed: int, label: str | None = None) -> dict:
    t0 = time.perf_counter()
    trace = build_dag_trace_soa(scn, profs, horizon_s, seed=seed)
    maybe_attach_timeline(trace)
    fabric = build_dag_fabric(scn, profs, _cfg(colocation))
    fm = fabric.serve_trace(trace)
    wall_s = time.perf_counter() - t0
    if label:
        maybe_dump_run(label, trace, fabric.nodes,
                       fabric.cfg.horizon_ms)
    f, j = fm.fleet, fm.jobs
    return {
        "requests": f.total,
        "completed": f.completed,
        "dropped": f.dropped,
        "conserved": f.completed + f.dropped == f.total,
        "stage_violation_rate": f.violation_rate,
        "jobs": j.jobs,
        "jobs_completed": j.completed,
        "jobs_failed": j.failed,
        "job_attainment": j.attainment,
        "job_latency_p50_ms": j.latency_p50_ms,
        "job_latency_p99_ms": j.latency_p99_ms,
        "wall_s": wall_s,
    }


def run_point(n_nodes: int, horizon_s: float = HORIZON_S,
              seed: int = SEED) -> dict:
    """Serve the same staged trace with and without co-location."""
    profs, _intf, _ = setup()
    scn = mixed_dag_scenario(n_nodes, slo_scale=SLO_SCALE)
    aware = _serve(scn, profs, True, horizon_s, seed,
                   label=f"dag_{n_nodes}n_colocated")
    obliv = _serve(scn, profs, False, horizon_s, seed,
                   label=f"dag_{n_nodes}n_oblivious")
    return {
        "n_nodes": n_nodes,
        "horizon_s": horizon_s,
        "aware": aware,
        "oblivious": obliv,
        "attainment_delta":
            aware["job_attainment"] - obliv["job_attainment"],
    }


def run(fast: bool = False) -> list[Row]:
    node_counts = (4,) if fast else NODE_COUNTS
    horizon_s = 6.0 if fast else HORIZON_S
    points = [run_point(n, horizon_s) for n in node_counts]
    if not fast:
        payload = {
            "benchmark": "dag_aware_vs_oblivious",
            "slo_scale": SLO_SCALE,
            "net_base_ms": NET_BASE_MS,
            "horizon_s": HORIZON_S,
            "points": points,
        }
        merge_bench_json(OUT_PATH, "dag", payload)
    rows = []
    for p in points:
        a, o = p["aware"], p["oblivious"]
        rows.append(Row(
            f"fabric/dag_{p['n_nodes']}n",
            (a["wall_s"] + o["wall_s"]) * 1e6,
            f"jobs={a['jobs']} requests={a['requests']} "
            f"attain={100*o['job_attainment']:.2f}%"
            f"->{100*a['job_attainment']:.2f}% "
            f"(+{100*p['attainment_delta']:.2f}pt) "
            f"p99={o['job_latency_p99_ms']:.0f}"
            f"->{a['job_latency_p99_ms']:.0f}ms"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3-node CI smoke: conservation + attainment bars")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)
    if not args.tiny:
        for row in run():
            print(row.csv())
        return 0
    p = run_point(3, horizon_s=8.0)
    a, o = p["aware"], p["oblivious"]
    print(f"dag-tiny n=3 jobs={a['jobs']} requests={a['requests']} "
          f"attain {100*o['job_attainment']:.2f}%->"
          f"{100*a['job_attainment']:.2f}% "
          f"p50 {a['job_latency_p50_ms']:.1f}ms "
          f"p99 {a['job_latency_p99_ms']:.1f}ms")
    if not (a["conserved"] and o["conserved"]):
        print("SMOKE FAIL: request conservation broken")
        return 1
    if a["jobs"] == 0:
        print("SMOKE FAIL: the scenario generated no DAG jobs")
        return 1
    if a["job_attainment"] < TINY_ATTAINMENT_FLOOR:
        print(f"SMOKE FAIL: aware job attainment "
              f"{a['job_attainment']:.3f} below the "
              f"{TINY_ATTAINMENT_FLOOR} floor")
        return 1
    if a["job_attainment"] < o["job_attainment"]:
        print("SMOKE FAIL: DAG-aware dispatch lost job attainment to "
              "stage-oblivious routing")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
