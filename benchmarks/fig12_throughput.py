"""Fig. 12: max achievable throughput per scheduler per scenario.

Paper: gpulet+int averages +102.6% vs SBP and +74.8% vs guided self-tuning;
gpulet is ~3.4% above gpulet+int (no interference conservatism).
"""
from __future__ import annotations

import statistics

from benchmarks.common import Row, make_schedulers, setup, timed
from repro.core.scenarios import APPLICATIONS, REQUEST_SCENARIOS


def throughput_table(profs, intf):
    rows = {}
    for sc, rates in REQUEST_SCENARIOS.items():
        scheds = make_schedulers(profs, intf)
        rows[sc] = {name: s.max_scale(rates) * sum(rates.values())
                    for name, s in scheds.items()}
    for app_name, app in APPLICATIONS.items():
        aprofs = app.profiles(profs)
        scheds = make_schedulers(aprofs, intf)
        rows[app_name] = {
            name: s.max_scale(app.stream_rates(1.0), hi=8192) * app.n_inferences
            for name, s in scheds.items()}
    return rows


def run(fast: bool = False) -> list[Row]:
    profs, intf, _ = setup()
    table, us = timed(throughput_table, profs, intf)
    out = []
    g_sbp, g_st, g_noint = [], [], []
    for sc, row in table.items():
        out.append(Row(
            f"fig12/{sc}", us / len(table),
            "  ".join(f"{k}={v:.0f}" for k, v in row.items())))
        g_sbp.append(row["gpulet+int"] / row["sbp"] - 1)
        g_st.append(row["gpulet+int"] / row["self-tuning"] - 1)
        g_noint.append(row["gpulet"] / row["gpulet+int"] - 1)
    out.append(Row(
        "fig12/avg_gains", 0.0,
        f"vs_sbp={100*statistics.mean(g_sbp):.1f}% (paper 102.6) "
        f"vs_selftuning={100*statistics.mean(g_st):.1f}% (paper 74.8) "
        f"gpulet_vs_int={100*statistics.mean(g_noint):.1f}% (paper 3.4)"))
    return out
