"""Engine scale sweep: requests/sec simulated at 1 / 8 / 64 nodes.

The perf-trajectory artifact for the serving hot path (ISSUE 4): a weak-
scaling ladder over the fabric — each node provisioned for ~500 req/s of
the mixed paper workload, 160 s horizon, so the top rung is a 64-node
fleet serving ≈5.1M requests in one simulated run.  Emits machine-
readable ``BENCH_engine.json`` at the repo root with, per rung:

  * ``requests`` / ``wall_s`` / ``req_per_s_simulated``
  * ``peak_rss_mb`` — process high-water RSS after the rung (self +
    forked node workers); cumulative by nature of ``ru_maxrss``
  * conservation + SLO summary, so a perf win that corrupts results is
    visible in the same file

CLI (use ``./run.sh`` so the allocator environment matches the
committed numbers)::

    ./run.sh python -m benchmarks.bench_engine           # ladder + JSON
    ./run.sh python -m benchmarks.bench_engine --smoke   # CI budget:
        100k requests through the fabric single-node path must finish
        under --budget-s wall seconds (exit 1 otherwise)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import resource
import time

from benchmarks.common import Row, setup
from repro.core.scenarios import (ENGINE_BENCH_HORIZON_S,
                                  ENGINE_BENCH_NODE_COUNTS,
                                  SWEEP_NODE_RATES, fabric_node_sweep)
from repro.fabric import (FabricConfig, NetworkModel, build_fabric,
                          build_trace_soa)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_engine.json")

#: PR-3's fig_fabric_scaling 16-node wall (520k requests, object-based
#: hot path) as recorded in BENCH_fabric.json at the PR-3 tip.  A
#: same-session interleaved re-measure of that commit on this machine
#: gave 21.4-28.5 s (the box's CPU quota fluctuates ~1.5x), so the
#: committed number is representative.  The SoA speedup below is
#: computed against it.
PR3_FABRIC_16N_WALL_S = 24.63


def _peak_rss_mb() -> float:
    """High-water RSS of this process and its (forked) children, MB."""
    self_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    child_kb = resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss
    return max(self_kb, child_kb) / 1024.0


def run_point(n_nodes: int, horizon_s: float, seed: int = 0,
              node_workers: int | None = None) -> dict:
    """One weak-scaling rung: build, trace, serve; everything timed."""
    profs, _intf, _ = setup()
    if node_workers is None:
        node_workers = os.cpu_count() or 1
    scn = fabric_node_sweep(node_counts=(n_nodes,))[0]
    cfg = FabricConfig(horizon_ms=horizon_s * 1e3, policy="least-loaded",
                       network=NetworkModel(base_ms=0.15, seed=seed),
                       preemption=True, node_workers=node_workers)
    t0 = time.perf_counter()
    fabric = build_fabric(scn, profs, cfg)
    for node in fabric.nodes:
        # multi-million-request rungs must not accumulate an event log
        node.cfg = dataclasses.replace(node.cfg, event_log=False)
    trace = build_trace_soa(scn, profs, horizon_s, seed=seed)
    fm = fabric.serve_trace(trace)
    wall = time.perf_counter() - t0
    total = fm.fleet.total
    return {
        "n_nodes": n_nodes,
        "horizon_s": horizon_s,
        "requests": total,
        "wall_s": wall,
        "req_per_s_simulated": total / wall if wall else 0.0,
        "peak_rss_mb": _peak_rss_mb(),
        "completed": fm.fleet.completed,
        "dropped": fm.fleet.dropped,
        "conserved": fm.fleet.completed + fm.fleet.dropped == total,
        "violation_rate": fm.violation_rate,
        "goodput_per_node_req_s": fm.goodput_req_s / n_nodes,
        "preemptions": fm.preemptions,
        "node_workers": node_workers,
    }


def run_sweep(node_counts=ENGINE_BENCH_NODE_COUNTS,
              horizon_s: float = ENGINE_BENCH_HORIZON_S,
              seed: int = 0) -> list[dict]:
    return [run_point(n, horizon_s, seed=seed) for n in node_counts]


def run(fast: bool = False) -> list[Row]:
    if fast:
        sweep = [run_point(n, 20.0) for n in (1, 2)]
    else:
        sweep = run_sweep()
        # the fig_fabric_scaling acceptance point: 16 nodes x 65 s
        # (520k requests), compared against the PR-3 object-path wall.
        # Best-of-2: shared-CPU containers fluctuate ~2x run to run, and
        # the minimum is the standard low-noise wall-clock estimator.
        fig16 = min((run_point(16, 65.0) for _ in range(2)),
                    key=lambda s: s["wall_s"])
        payload = {
            "benchmark": "engine_scale",
            "per_node_rates": SWEEP_NODE_RATES,
            "policy": "least-loaded",
            "preemption": True,
            "sweep": sweep,
            "fig_fabric_scaling_16n": {
                **fig16,
                "pr3_baseline_wall_s": PR3_FABRIC_16N_WALL_S,
                "speedup_vs_pr3": PR3_FABRIC_16N_WALL_S / fig16["wall_s"],
            },
        }
        with open(OUT_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        sweep = sweep + [fig16]
    rows = []
    for s in sweep:
        rows.append(Row(
            f"engine/scale_{s['n_nodes']}n", s["wall_s"] * 1e6,
            f"requests={s['requests']} "
            f"sim={s['req_per_s_simulated']:,.0f}req/s "
            f"rss={s['peak_rss_mb']:.0f}MB "
            f"viol={100 * s['violation_rate']:.2f}% "
            f"conserved={s['conserved']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI timing budget: 100k requests, 1 node")
    ap.add_argument("--budget-s", type=float, default=20.0,
                    help="wall-clock budget for --smoke")
    args = ap.parse_args()
    if not args.smoke:
        for row in run():
            print(row.csv())
        return 0
    # --smoke: 100k requests through the fabric single-node path.  The
    # budget has ~10x headroom over the SoA hot path on a busy CI runner,
    # so only a hot-path regression (or a return to per-object serving,
    # which is several times over) trips it.
    per_node_rate = sum(SWEEP_NODE_RATES.values())
    horizon_s = 100_000 / per_node_rate
    s = run_point(1, horizon_s, node_workers=1)
    ok = s["wall_s"] <= args.budget_s and s["conserved"]
    print(f"engine-smoke requests={s['requests']} wall={s['wall_s']:.2f}s "
          f"budget={args.budget_s:.0f}s conserved={s['conserved']} "
          f"viol={100 * s['violation_rate']:.2f}% "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        print("SMOKE FAIL: serving hot path over wall-clock budget "
              "(or conservation broken)")
        return 1
    # the migration epoch loop rides the same wall budget: a drifting
    # 2-node fleet with live rescheduling must stay cheap — the epoch
    # dispatch + per-delta partition solves are not allowed to dominate
    # the serving hot path.
    from benchmarks.fig_migration import run_point as migration_point
    t0 = time.perf_counter()
    p = migration_point(2, horizon_s=20.0)
    mig_wall = time.perf_counter() - t0
    m = p["migration"]
    ok = mig_wall <= args.budget_s and m["conserved"] \
        and p["reroute_only"]["conserved"]
    print(f"engine-smoke-migration requests={m['requests']} "
          f"wall={mig_wall:.2f}s budget={args.budget_s:.0f}s "
          f"migrations={m['migrations']} conserved={m['conserved']} "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        print("SMOKE FAIL: migration serving path over wall-clock "
              "budget (or conservation broken)")
        return 1
    # the DAG release-frontier loop rides the same wall budget too: the
    # per-epoch frontier scans + incremental engine segments (hundreds of
    # run_until slices per node) must not dominate the serving hot path.
    from benchmarks.fig_dag import run_point as dag_point
    t0 = time.perf_counter()
    p = dag_point(3, horizon_s=8.0)
    dag_wall = time.perf_counter() - t0
    d = p["aware"]
    ok = dag_wall <= args.budget_s and d["conserved"] \
        and p["oblivious"]["conserved"]
    print(f"engine-smoke-dag requests={d['requests']} jobs={d['jobs']} "
          f"wall={dag_wall:.2f}s budget={args.budget_s:.0f}s "
          f"conserved={d['conserved']} {'OK' if ok else 'FAIL'}")
    if not ok:
        print("SMOKE FAIL: DAG serving path over wall-clock budget "
              "(or conservation broken)")
        return 1
    # the streaming continuous-batching walk rides the same wall budget:
    # per-chunk decode-pool bookkeeping (one heap event per chunk, pool
    # membership churn every launch) must stay in the same cost class as
    # the opaque-batch walk.
    from benchmarks.fig_streaming import run_point as streaming_point
    t0 = time.perf_counter()
    p = streaming_point(2, horizon_s=6.0)
    stream_wall = time.perf_counter() - t0
    st = p["aware"]
    ok = stream_wall <= args.budget_s and st["conserved"] \
        and st["tokens_ok"] and p["oblivious"]["conserved"]
    print(f"engine-smoke-streaming streams={st['streams']} "
          f"wall={stream_wall:.2f}s budget={args.budget_s:.0f}s "
          f"ttft={100 * st['ttft_attainment']:.2f}% "
          f"conserved={st['conserved']} {'OK' if ok else 'FAIL'}")
    if not ok:
        print("SMOKE FAIL: streaming serving path over wall-clock "
              "budget (or conservation/token accounting broken)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
