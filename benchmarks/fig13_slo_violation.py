"""Fig. 13: measured SLO-violation rates at each scheduler's claimed max.

Paper: plain gpulet exceeds 1% violations on some scenarios it declared
schedulable; gpulet+int filters those (all < 1%).
"""
from __future__ import annotations

from benchmarks.common import Row, setup, timed
from repro.core import ElasticPartitioning
from repro.core.scenarios import REQUEST_SCENARIOS
from repro.simulator import EngineConfig, EventHeapEngine, PoissonArrivals
from repro.simulator.events import merge_sorted


def violation_at_max(sched, profs, rates, horizon_ms=20_000.0, seed=42):
    lam = sched.max_scale(rates)
    use = {m: r * lam * 0.999 for m, r in rates.items() if r > 0}
    res = sched.schedule(use)
    gen = PoissonArrivals(seed=seed)
    reqs = merge_sorted([gen.constant(m, r, profs[m].slo_ms, horizon_ms)
                         for m, r in use.items()])
    eng = EventHeapEngine(
        profs, EngineConfig(horizon_ms=horizon_ms, acc=sched.acc),
        schedule=res)
    eng.submit(reqs)
    met = eng.run()
    return sum(use.values()), met.violation_rate


def run(fast: bool = False) -> list[Row]:
    profs, intf, _ = setup()
    horizon = 8_000.0 if fast else 20_000.0
    rows = []
    viols: dict[str, list[float]] = {}
    for name, sched in (("gpulet", ElasticPartitioning(profs)),
                        ("gpulet+int",
                         ElasticPartitioning(profs, intf_model=intf))):
        for sc, rates in REQUEST_SCENARIOS.items():
            (rate, viol), us = timed(violation_at_max, sched, profs, rates,
                                     horizon)
            flag = "VIOLATES>1%" if viol > 0.01 else "ok(<1%)"
            viols.setdefault(name, []).append(viol)
            rows.append(Row(f"fig13/{name}/{sc}", us,
                            f"rate={rate:.0f}/s violation={100*viol:.2f}% "
                            f"{flag}"))
    # The paper's Fig. 13 contrast: plain gpulet (interference-blind
    # admission) exceeds 1% violations on some scenarios it declared
    # schedulable; gpulet+int books predicted factors and filters those.
    plain_exceeds = any(v > 0.01 for v in viols.get("gpulet", []))
    int_all_ok = all(v <= 0.01 for v in viols.get("gpulet+int", [1.0]))
    rows.append(Row("fig13/summary", 0.0,
                    f"gpulet_exceeds_1pct_somewhere={plain_exceeds} "
                    f"gpulet+int_all_below_1pct={int_all_ok} "
                    f"contrast_restored={plain_exceeds and int_all_ok} "
                    "(paper: both True)"))
    return rows
