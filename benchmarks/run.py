"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--fast`` shrinks the expensive
simulations/exhaustive searches for CI use.
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "benchmarks.fig03_latency_curves",
    "benchmarks.fig04_schedulability",
    "benchmarks.fig06_interference_cdf",
    "benchmarks.fig09_intf_model_error",
    "benchmarks.fig12_throughput",
    "benchmarks.fig13_slo_violation",
    "benchmarks.fig14_fluctuation",
    "benchmarks.fig15_ideal_comparison",
    "benchmarks.fig_fabric_scaling",
    "benchmarks.fig_migration",
    "benchmarks.fig_dag",
    "benchmarks.fig_streaming",
    "benchmarks.bench_engine",
    "benchmarks.kernels_bench",
    "benchmarks.ablations",
    "benchmarks.roofline",
    "benchmarks.tpulet_serving",
]


def main() -> int:
    from benchmarks.common import add_trace_dir_arg, set_trace_dir

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)

    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for row in mod.run(fast=args.fast):
                print(row.csv())
                sys.stdout.flush()
        except Exception:
            failed += 1
            print(f"{modname},0,ERROR")
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
