"""Shared benchmark plumbing: calibrated profiles, schedulers, timing."""
from __future__ import annotations

import functools
import time

from repro.core import (ElasticPartitioning, GuidedSelfTuning,
                        SquishyBinPacking, calibrate_profiles,
                        fit_default_model)


@functools.lru_cache(maxsize=1)
def setup():
    profs_t = calibrate_profiles()
    intf, intf_stats = fit_default_model(profs_t)
    return profs_t, intf, intf_stats


def make_schedulers(profiles, intf):
    return {
        "sbp": SquishyBinPacking(profiles),
        "self-tuning": GuidedSelfTuning(profiles),
        "gpulet": ElasticPartitioning(profiles),
        "gpulet+int": ElasticPartitioning(profiles, intf_model=intf),
    }


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


class Row:
    """One CSV row: name, us_per_call, derived."""

    def __init__(self, name: str, us: float, derived: str):
        self.name, self.us, self.derived = name, us, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"
