"""Shared benchmark plumbing: calibrated profiles, schedulers, timing."""
from __future__ import annotations

import functools
import json
import os
import time

from repro.core import (ElasticPartitioning, GuidedSelfTuning,
                        SquishyBinPacking, calibrate_profiles,
                        fit_default_model)


@functools.lru_cache(maxsize=1)
def setup():
    profs_t = calibrate_profiles()
    intf, intf_stats = fit_default_model(profs_t)
    return profs_t, intf, intf_stats


# ---- observability plumbing (--trace-dir) ---------------------------------

#: destination for lifecycle/telemetry artifacts; None = tracing off
#: (the default — benchmarks pay zero observability overhead)
_TRACE_DIR: str | None = None


def set_trace_dir(path: str | None) -> None:
    """Enable SLO-forensics export for subsequent benchmark runs."""
    global _TRACE_DIR
    if path:
        os.makedirs(path, exist_ok=True)
    _TRACE_DIR = path or None


def trace_dir() -> str | None:
    return _TRACE_DIR


def add_trace_dir_arg(ap) -> None:
    ap.add_argument(
        "--trace-dir", default=None, metavar="DIR",
        help="export per-request lifecycle traces, fleet time-series "
             "JSONL, SLO-miss attribution, and a Chrome/Perfetto trace "
             "per run into DIR (see repro.obs)")


def maybe_attach_timeline(trace):
    """Attach an obs timeline when --trace-dir is active.

    Must run before dispatch: the timeline snapshots pristine
    arrival/SLO columns.  Returns ``trace`` for chaining.
    """
    if _TRACE_DIR is not None:
        from repro.obs import attach_timeline
        attach_timeline(trace)
    return trace


def maybe_dump_run(label: str, trace, nodes, horizon_ms: float,
                   migration_events=()) -> dict | None:
    """Write the run's obs artifacts into the active trace dir, if any."""
    if _TRACE_DIR is None or getattr(trace, "obs", None) is None:
        return None
    from repro.obs import dump_run
    return dump_run(_TRACE_DIR, label, trace, nodes, horizon_ms,
                    migration_events=migration_events)


def make_schedulers(profiles, intf):
    return {
        "sbp": SquishyBinPacking(profiles),
        "self-tuning": GuidedSelfTuning(profiles),
        "gpulet": ElasticPartitioning(profiles),
        "gpulet+int": ElasticPartitioning(profiles, intf_model=intf),
    }


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


def merge_bench_json(path: str, key: str, payload: dict) -> None:
    """Write ``payload`` under ``key`` in a shared benchmark JSON file.

    Several benchmarks share one trajectory artifact (BENCH_fabric.json
    holds both the scaling sweep and the migration contrast); each
    read-modify-writes only its own top-level key, so re-running one
    benchmark never clobbers the other's numbers.  Pre-PR-5 flat files
    (one payload at the top level, recognizable by their ``benchmark``
    field) are folded under their own name on first contact.
    """
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    if "benchmark" in doc:            # legacy flat layout
        doc = {doc["benchmark"]: doc}
    doc[key] = payload
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


class Row:
    """One CSV row: name, us_per_call, derived."""

    def __init__(self, name: str, us: float, derived: str):
        self.name, self.us, self.derived = name, us, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"
