"""Shared benchmark plumbing: calibrated profiles, schedulers, timing."""
from __future__ import annotations

import functools
import json
import os
import time

from repro.core import (ElasticPartitioning, GuidedSelfTuning,
                        SquishyBinPacking, calibrate_profiles,
                        fit_default_model)


@functools.lru_cache(maxsize=1)
def setup():
    profs_t = calibrate_profiles()
    intf, intf_stats = fit_default_model(profs_t)
    return profs_t, intf, intf_stats


def make_schedulers(profiles, intf):
    return {
        "sbp": SquishyBinPacking(profiles),
        "self-tuning": GuidedSelfTuning(profiles),
        "gpulet": ElasticPartitioning(profiles),
        "gpulet+int": ElasticPartitioning(profiles, intf_model=intf),
    }


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


def merge_bench_json(path: str, key: str, payload: dict) -> None:
    """Write ``payload`` under ``key`` in a shared benchmark JSON file.

    Several benchmarks share one trajectory artifact (BENCH_fabric.json
    holds both the scaling sweep and the migration contrast); each
    read-modify-writes only its own top-level key, so re-running one
    benchmark never clobbers the other's numbers.  Pre-PR-5 flat files
    (one payload at the top level, recognizable by their ``benchmark``
    field) are folded under their own name on first contact.
    """
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    if "benchmark" in doc:            # legacy flat layout
        doc = {doc["benchmark"]: doc}
    doc[key] = payload
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


class Row:
    """One CSV row: name, us_per_call, derived."""

    def __init__(self, name: str, us: float, derived: str):
        self.name, self.us, self.derived = name, us, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"
