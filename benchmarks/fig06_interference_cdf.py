"""Fig. 6: CDF of interference-induced latency overhead for co-located pairs.

Paper: ~90% of consolidated scenarios below 18% overhead, with a long tail.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, setup, timed
from repro.core.interference import profile_pairs_dataset


def run(fast: bool = False) -> list[Row]:
    profs, _, _ = setup()
    (feats, targs, recs), us = timed(profile_pairs_dataset, profs)
    ov = targs - 1.0
    p50, p90, p99 = np.percentile(ov, [50, 90, 99])
    frac18 = float(np.mean(ov < 0.18))
    return [Row("fig06/interference_cdf", us,
                f"n={len(targs)} p50={p50:.3f} p90={p90:.3f} p99={p99:.3f} "
                f"max={ov.max():.3f} frac_below_18pct={frac18:.3f} "
                f"(paper: ~0.90)")]
