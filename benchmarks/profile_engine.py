"""Hot-path profiler: where does a 100k-request serving run spend time?

First-class tooling for perf PRs (ISSUE 4 satellite): runs a seeded
100k-request trace through the fabric single-node path under cProfile and
prints the top-N functions, so a regression (or the next optimisation
target) is one command away:

    PYTHONPATH=src python -m benchmarks.profile_engine
    PYTHONPATH=src python -m benchmarks.profile_engine --requests 500000 \\
        --nodes 4 --sort tottime --top 30

The default configuration mirrors ``benchmarks.fig_fabric_scaling``'s
per-node workload (~500 req/s of the mixed paper models, 20% gold / 50%
silver / 30% bronze, preemption on) so profiles line up with the tracked
benchmark numbers.  The event log is disabled, like the benchmarks.
"""
from __future__ import annotations

import argparse
import cProfile
import dataclasses
import io
import pstats
import time

from benchmarks.common import setup
from repro.core.scenarios import SWEEP_NODE_RATES, fabric_node_sweep
from repro.fabric import (FabricConfig, NetworkModel, build_fabric,
                          build_trace_soa)


def profile_run(n_requests: int = 100_000, n_nodes: int = 1,
                sort: str = "cumulative", top: int = 20,
                seed: int = 0) -> pstats.Stats:
    profs, _intf, _ = setup()
    per_node_rate = sum(SWEEP_NODE_RATES.values())
    horizon_s = n_requests / (per_node_rate * n_nodes)
    scn = fabric_node_sweep(node_counts=(n_nodes,))[0]
    cfg = FabricConfig(horizon_ms=horizon_s * 1e3, policy="least-loaded",
                       network=NetworkModel(base_ms=0.15, seed=seed),
                       preemption=True)
    fabric = build_fabric(scn, profs, cfg)
    for node in fabric.nodes:
        node.cfg = dataclasses.replace(node.cfg, event_log=False)
    trace = build_trace_soa(scn, profs, horizon_s, seed=seed)
    pr = cProfile.Profile()
    t0 = time.perf_counter()
    pr.enable()
    fm = fabric.serve_trace(trace)
    pr.disable()
    wall = time.perf_counter() - t0
    out = io.StringIO()
    stats = pstats.Stats(pr, stream=out)
    stats.sort_stats(sort).print_stats(top)
    print(f"# {len(trace)} requests, {n_nodes} node(s), "
          f"{wall:.2f}s wall under profiler "
          f"({len(trace) / wall:,.0f} req/s simulated), "
          f"completed={fm.fleet.completed} dropped={fm.fleet.dropped}")
    print(out.getvalue())
    return stats


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=100_000,
                    help="approximate fleet-total request count")
    ap.add_argument("--nodes", type=int, default=1)
    ap.add_argument("--sort", default="cumulative",
                    choices=["cumulative", "tottime", "ncalls"])
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    profile_run(args.requests, args.nodes, args.sort, args.top, args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
