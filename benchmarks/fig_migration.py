"""Global rescheduling vs re-route-only on a drifting-zipf fleet (ISSUE 5).

Beyond-paper (ROADMAP "fabric-level global rescheduling"): the fleet's
popularity mix drifts — the Zipf rank-1 model rotates onto what the
partitioned placement provisioned as the coldest model — and the same
seeded trace is served twice per fleet size:

  * **re-route-only** — the PR-3/4 fabric: placement frozen at build
    time, the router's shed/re-route/preempt machinery absorbs what it
    can.  Capacity is stranded on nodes serving yesterday's hot model.
  * **migration** — the PR-5 global rescheduler moves placement live:
    bounded per-epoch deltas, warm-up charges on receivers, donors
    draining to their cut.

Reports per-class SLO *attainment* (1 - violation rate) and total
goodput; the acceptance bar is migration beating re-route-only on
gold-class attainment AND goodput at every fleet size.  Results merge
into ``BENCH_fabric.json`` under the ``"migration"`` key (alongside the
scaling sweep's ``"fabric_scaling"``).

CLI: ``python -m benchmarks.fig_migration --tiny`` runs a 3-node CI
smoke and exits non-zero on conservation breaks or a migration loss.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import (Row, add_trace_dir_arg, maybe_attach_timeline,
                               maybe_dump_run, merge_bench_json,
                               set_trace_dir, setup)
from repro.core.scenarios import drifting_zipf_scenario
from repro.fabric import FabricConfig, build_fabric, build_trace_soa
from repro.fabric.priority import CLASS_NAMES

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fabric.json")

#: the drifting-zipf operating point: hot-model share ~68% (skew 2.4),
#: fleet at ~110% of the placement heuristic's capacity — hard enough
#: that gold bleeds on the stranded homes, so migration has something
#: to win on every class
SKEW = 2.4
UTIL = 1.1
HORIZON_S = 36.0
N_PHASES = 3
NODE_COUNTS = (8, 16)
MIGRATION_PERIOD_MS = 2_000.0
MAX_MIGRATIONS_PER_EPOCH = 4


def _cfg(migrations: bool, horizon_s: float,
         period_ms: float = MIGRATION_PERIOD_MS) -> FabricConfig:
    return FabricConfig(
        horizon_ms=horizon_s * 1e3, policy="least-loaded",
        preemption=True, migrations=migrations,
        migration_period_ms=period_ms,
        max_migrations_per_epoch=MAX_MIGRATIONS_PER_EPOCH,
        node_workers=os.cpu_count() or 1)


def _serve(scn, profs, cfg, horizon_s: float, seed: int,
           label: str | None = None) -> dict:
    t0 = time.perf_counter()
    fabric = build_fabric(scn, profs, cfg)
    trace = build_trace_soa(scn, profs, horizon_s, seed=seed)
    maybe_attach_timeline(trace)
    fm = fabric.serve_trace(trace)
    wall_s = time.perf_counter() - t0
    if label:
        maybe_dump_run(label, trace, fabric.nodes, cfg.horizon_ms,
                       migration_events=fm.migration_events)
    per_class = {}
    for level, pc in sorted(fm.fleet.per_class.items()):
        per_class[CLASS_NAMES.get(level, str(level))] = {
            "total": pc["total"],
            "violations": pc["violations"],
            "slo_attainment": 1.0 - pc["violations"] / max(pc["total"], 1),
        }
    return {
        "requests": fm.fleet.total,
        "completed": fm.fleet.completed,
        "dropped": fm.fleet.dropped,
        "conserved": fm.fleet.completed + fm.fleet.dropped
        == fm.fleet.total,
        "goodput_req_s": fm.goodput_req_s,
        "violation_rate": fm.violation_rate,
        "per_class": per_class,
        "migrations": fm.migrations,
        "handed_back": fm.stats.handed_back,
        "shed": {str(k): v for k, v in sorted(fm.stats.shed.items())},
        "wall_s": wall_s,
    }


def run_point(n_nodes: int, horizon_s: float = HORIZON_S,
              seed: int = 0, skew: float = SKEW,
              util: float = UTIL) -> dict:
    """Serve the same drifting trace with and without migrations."""
    profs, _intf, _ = setup()
    scn = drifting_zipf_scenario(n_nodes, horizon_s=horizon_s,
                                 n_phases=N_PHASES, skew=skew, util=util)
    base = _serve(scn, profs, _cfg(False, horizon_s), horizon_s, seed,
                  label=f"migration_{n_nodes}n_reroute_only")
    mig = _serve(scn, profs, _cfg(True, horizon_s), horizon_s, seed,
                 label=f"migration_{n_nodes}n_migration")
    return {
        "n_nodes": n_nodes,
        "horizon_s": horizon_s,
        "skew": skew,
        "util": util,
        "reroute_only": base,
        "migration": mig,
        "gold_attainment_delta":
            mig["per_class"]["gold"]["slo_attainment"]
            - base["per_class"]["gold"]["slo_attainment"],
        "goodput_gain":
            mig["goodput_req_s"] / max(base["goodput_req_s"], 1e-9),
    }


def run(fast: bool = False) -> list[Row]:
    node_counts = (4,) if fast else NODE_COUNTS
    horizon_s = 18.0 if fast else HORIZON_S
    points = [run_point(n, horizon_s) for n in node_counts]
    if not fast:
        payload = {
            "benchmark": "migration_vs_reroute",
            "drift": {"skew": SKEW, "util": UTIL, "n_phases": N_PHASES,
                      "horizon_s": HORIZON_S},
            "migration_period_ms": MIGRATION_PERIOD_MS,
            "max_migrations_per_epoch": MAX_MIGRATIONS_PER_EPOCH,
            "points": points,
        }
        merge_bench_json(OUT_PATH, "migration", payload)
    rows = []
    for p in points:
        b, m = p["reroute_only"], p["migration"]
        rows.append(Row(
            f"fabric/migration_{p['n_nodes']}n",
            (b["wall_s"] + m["wall_s"]) * 1e6,
            f"requests={b['requests']} "
            f"gold_attain={100*b['per_class']['gold']['slo_attainment']:.2f}%"
            f"->{100*m['per_class']['gold']['slo_attainment']:.2f}% "
            f"goodput={b['goodput_req_s']:.0f}->{m['goodput_req_s']:.0f}"
            f"req/s (x{p['goodput_gain']:.2f}) "
            f"migrations={m['migrations']} handed_back={m['handed_back']}"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3-node CI smoke: conservation + migration win")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)
    if not args.tiny:
        for row in run():
            print(row.csv())
        return 0
    p = run_point(3, horizon_s=15.0)
    b, m = p["reroute_only"], p["migration"]
    print(f"migration-tiny n=3 requests={b['requests']} "
          f"migrations={m['migrations']} "
          f"goodput {b['goodput_req_s']:.0f}->{m['goodput_req_s']:.0f} "
          f"gold {100*b['per_class']['gold']['slo_attainment']:.2f}%->"
          f"{100*m['per_class']['gold']['slo_attainment']:.2f}%")
    if not (b["conserved"] and m["conserved"]):
        print("SMOKE FAIL: request conservation broken")
        return 1
    if m["migrations"] == 0:
        print("SMOKE FAIL: the drift never triggered a migration")
        return 1
    if m["goodput_req_s"] < b["goodput_req_s"]:
        print("SMOKE FAIL: migration lost goodput to re-route-only")
        return 1
    if m["per_class"]["gold"]["slo_attainment"] \
            < b["per_class"]["gold"]["slo_attainment"]:
        print("SMOKE FAIL: migration lost gold-class SLO attainment "
              "to re-route-only")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
