"""Fig. 3: batch latency vs. partition size — flat for small b, steep for 32."""
from __future__ import annotations

from benchmarks.common import Row, setup, timed
from repro.core.latency import AnalyticGPULatency, PARTITION_SIZES


def run(fast: bool = False) -> list[Row]:
    profs, _, _ = setup()
    lat = AnalyticGPULatency()
    rows = []
    for name in ("goo", "res", "ssd", "vgg"):
        prof = profs[name]

        def curve():
            return {b: [lat.latency_ms(prof, b, s / 100)
                        for s in PARTITION_SIZES] for b in (1, 8, 32)}

        c, us = timed(curve)
        # knee check: latency ratio L(20%)/L(100%) small for b=1, large b=32
        r1 = c[1][0] / c[1][-1]
        r32 = c[32][0] / c[32][-1]
        knee = lat.max_efficient_partition(prof)
        rows.append(Row(
            f"fig03/{name}", us,
            f"L20/L100[b=1]={r1:.2f} L20/L100[b=32]={r32:.2f} knee={knee}% "
            f"flat_small_batch={'yes' if r1 < r32 / 1.5 else 'no'}"))
    return rows
