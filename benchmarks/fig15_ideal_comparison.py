"""Fig. 15/16: elastic partitioning vs. the exhaustive ideal scheduler.

Paper: gpulet+int schedules 18 fewer of 1023 scenarios (1.8%) and reaches an
average 92.3% of the ideal max schedulable rate.
"""
from __future__ import annotations

import statistics

from benchmarks.common import Row, setup, timed
from repro.core import ElasticPartitioning, IdealScheduler
from repro.core.scenarios import APPLICATIONS, REQUEST_SCENARIOS, \
    schedulability_population


def run(fast: bool = False) -> list[Row]:
    profs, intf, _ = setup()
    ours = ElasticPartitioning(profs, intf_model=intf)
    ideal = IdealScheduler(profs, intf_model=intf)
    pop = schedulability_population()
    pop = pop[::16] if fast else pop[::4]  # ideal is exhaustive: subsample

    def count(s):
        return sum(1 for r in pop if s.is_schedulable(r))

    n_ours, us1 = timed(count, ours)
    n_ideal, us2 = timed(count, ideal)
    rows = [Row("fig15/schedulability", us1 + us2,
                f"gpulet+int={n_ours}/{len(pop)} ideal={n_ideal}/{len(pop)} "
                f"gap={n_ideal - n_ours} "
                f"({100*(n_ideal-n_ours)/len(pop):.1f}%, paper 1.8%)")]

    ratios = []
    scenarios = list(REQUEST_SCENARIOS.items())
    if fast:
        scenarios = scenarios[:1]
    for sc, rates in scenarios:
        (lam_o, lam_i), us = timed(
            lambda: (ours.max_scale(rates), ideal.max_scale(rates)))
        ratio = lam_o / lam_i if lam_i else 1.0
        ratios.append(ratio)
        rows.append(Row(f"fig16/{sc}", us,
                        f"ours={lam_o:.2f}x ideal={lam_i:.2f}x "
                        f"ratio={100*ratio:.1f}%"))
    if not fast:
        for app_name, app in APPLICATIONS.items():
            aprofs = app.profiles(profs)
            o = ElasticPartitioning(aprofs, intf_model=intf)
            i = IdealScheduler(aprofs, intf_model=intf)
            (lo, li), us = timed(lambda: (
                o.max_scale(app.stream_rates(1.0), hi=8192),
                i.max_scale(app.stream_rates(1.0), hi=8192)))
            ratio = lo / li if li else 1.0
            ratios.append(ratio)
            rows.append(Row(f"fig16/{app_name}", us,
                            f"ours={lo:.0f} ideal={li:.0f} "
                            f"ratio={100*ratio:.1f}%"))
    rows.append(Row("fig16/avg", 0.0,
                    f"avg_ratio={100*statistics.mean(ratios):.1f}% "
                    f"(paper 92.3%)"))
    return rows
