"""Naive failover vs the full recovery stack under a fault storm (ISSUE 9).

The same seeded trace and the same seeded fault storm — a transient
crash (with re-warm), a permanent crash, straggler windows, and a
degraded-network window with dispatch loss — are served twice per fleet
size:

  * **naive** — failures drain through a flat legacy-style failover lag
    (one replay, ``failover_ms`` backoff, no health state): the router
    keeps dispatching into dead nodes until their RPCs time out, and
    replays that cannot meet their deadline are dispatched anyway.
  * **recovery** — the PR-9 stack: EWMA health detection (suspect /
    evict / probe / reinstate) learned from observed outcomes, deadline-
    aware retry budgets with exponential backoff (hopeless replays are
    shed, not replayed), and the brownout ladder shedding bronze first
    when sustained gold-class miss pressure says the fleet is drowning.

Reports per-class SLO attainment and goodput; the acceptance bar is
recovery beating naive on gold-class attainment at every fleet size.
Results merge into ``BENCH_fabric.json`` under the ``"chaos"`` key.

CLI: ``python -m benchmarks.fig_chaos --tiny`` runs a 3-node CI smoke
and exits non-zero on a conservation break or a recovery loss.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import (Row, add_trace_dir_arg, maybe_attach_timeline,
                               maybe_dump_run, merge_bench_json,
                               set_trace_dir, setup)
from repro.core.scenarios import fabric_node_sweep
from repro.fabric import (FabricConfig, build_fabric, build_trace_soa,
                          chaos_plan)
from repro.fabric.priority import CLASS_NAMES

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fabric.json")

HORIZON_S = 20.0
NODE_COUNTS = (4, 8)
STORM_SEED = 7


def _storm(n_nodes: int, horizon_s: float, seed: int):
    """One transient + one permanent crash, stragglers and a lossy
    network window scaled with the fleet."""
    return chaos_plan(n_nodes, horizon_s * 1e3, seed=seed,
                      n_transient=max(1, n_nodes // 4),
                      n_permanent=1,
                      n_stragglers=max(1, n_nodes // 4),
                      n_net=1)


def _cfg(plan, recovery: bool, horizon_s: float) -> FabricConfig:
    return FabricConfig(
        horizon_ms=horizon_s * 1e3, policy="least-loaded",
        preemption=True, faults=plan, recovery=recovery)


def _serve(scn, profs, cfg, horizon_s: float, seed: int,
           label: str | None = None) -> dict:
    t0 = time.perf_counter()
    fabric = build_fabric(scn, profs, cfg)
    trace = build_trace_soa(scn, profs, horizon_s, seed=seed)
    maybe_attach_timeline(trace)
    fm = fabric.serve_trace(trace)
    wall_s = time.perf_counter() - t0
    if label:
        maybe_dump_run(label, trace, fabric.nodes, cfg.horizon_ms,
                       migration_events=fm.migration_events)
    per_class = {}
    for level, pc in sorted(fm.fleet.per_class.items()):
        per_class[CLASS_NAMES.get(level, str(level))] = {
            "total": pc["total"],
            "violations": pc["violations"],
            "slo_attainment": 1.0 - pc["violations"] / max(pc["total"], 1),
        }
    ch = fm.chaos or {}
    det = ch.get("detector") or {}
    brown = ch.get("brownout") or {}
    return {
        "requests": fm.fleet.total,
        "completed": fm.fleet.completed,
        "dropped": fm.fleet.dropped,
        "conserved": fm.fleet.completed + fm.fleet.dropped
        == fm.fleet.total,
        "goodput_req_s": fm.goodput_req_s,
        "violation_rate": fm.violation_rate,
        "per_class": per_class,
        "retries": ch.get("retries", 0),
        "retry_drops": ch.get("retry_drops", 0),
        "net_lost": ch.get("net_lost", 0),
        "health_events": det.get("events", []),
        "brownout_events": brown.get("events", []),
        "brownout_denied": brown.get("denied", 0),
        "wall_s": wall_s,
    }


def run_point(n_nodes: int, horizon_s: float = HORIZON_S,
              seed: int = STORM_SEED) -> dict:
    """Serve the same trace through the same storm, both arms."""
    profs, _intf, _ = setup()
    scn = fabric_node_sweep(node_counts=(n_nodes,))[0]
    plan = _storm(n_nodes, horizon_s, seed)
    naive = _serve(scn, profs, _cfg(plan, False, horizon_s), horizon_s,
                   seed, label=f"chaos_{n_nodes}n_naive")
    rec = _serve(scn, profs, _cfg(plan, True, horizon_s), horizon_s,
                 seed, label=f"chaos_{n_nodes}n_recovery")
    return {
        "n_nodes": n_nodes,
        "horizon_s": horizon_s,
        "storm_seed": seed,
        "n_faults": len(plan.faults),
        "naive": naive,
        "recovery": rec,
        "gold_attainment_delta":
            rec["per_class"]["gold"]["slo_attainment"]
            - naive["per_class"]["gold"]["slo_attainment"],
        "goodput_gain":
            rec["goodput_req_s"] / max(naive["goodput_req_s"], 1e-9),
    }


def run(fast: bool = False) -> list[Row]:
    node_counts = (4,) if fast else NODE_COUNTS
    horizon_s = 10.0 if fast else HORIZON_S
    points = [run_point(n, horizon_s) for n in node_counts]
    if not fast:
        payload = {
            "benchmark": "chaos_naive_vs_recovery",
            "horizon_s": HORIZON_S,
            "storm_seed": STORM_SEED,
            "points": points,
        }
        merge_bench_json(OUT_PATH, "chaos", payload)
    rows = []
    for p in points:
        b, r = p["naive"], p["recovery"]
        rows.append(Row(
            f"fabric/chaos_{p['n_nodes']}n",
            (b["wall_s"] + r["wall_s"]) * 1e6,
            f"requests={b['requests']} faults={p['n_faults']} "
            f"gold_attain={100*b['per_class']['gold']['slo_attainment']:.2f}%"
            f"->{100*r['per_class']['gold']['slo_attainment']:.2f}% "
            f"goodput={b['goodput_req_s']:.0f}->{r['goodput_req_s']:.0f}"
            f"req/s (x{p['goodput_gain']:.2f}) "
            f"retries={r['retries']} retry_drops={r['retry_drops']} "
            f"evictions={sum(1 for e in r['health_events'] if e[2] == 'evicted')}"))
    return rows


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3-node CI smoke: conservation + recovery win")
    add_trace_dir_arg(ap)
    args = ap.parse_args()
    set_trace_dir(args.trace_dir)
    if not args.tiny:
        for row in run():
            print(row.csv())
        return 0
    p = run_point(3, horizon_s=8.0)
    b, r = p["naive"], p["recovery"]
    print(f"chaos-tiny n=3 requests={b['requests']} "
          f"faults={p['n_faults']} "
          f"gold {100*b['per_class']['gold']['slo_attainment']:.2f}%->"
          f"{100*r['per_class']['gold']['slo_attainment']:.2f}% "
          f"retries={r['retries']} retry_drops={r['retry_drops']} "
          f"health_events={len(r['health_events'])}")
    if not (b["conserved"] and r["conserved"]):
        print("SMOKE FAIL: request conservation broken under the storm")
        return 1
    if not r["health_events"]:
        print("SMOKE FAIL: the storm never tripped the health detector")
        return 1
    if p["gold_attainment_delta"] <= 0:
        print("SMOKE FAIL: recovery lost gold-class SLO attainment "
              "to naive failover")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
