"""§Roofline: per-(arch x shape x mesh) roofline terms from the dry-run.

Reads results/dryrun.jsonl (launch/dryrun.py output).  One row per combo:
the three terms in seconds, the dominant bottleneck, and the useful-FLOP
ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Row

DEFAULT_PATHS = ("results/dryrun.jsonl", "results/dryrun_mp.jsonl",
                 "results/dryrun_opt.jsonl")


def load_records(paths=DEFAULT_PATHS):
    recs = []
    for path in paths:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                try:
                    recs.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    return recs


def run(fast: bool = False) -> list[Row]:
    recs = load_records()
    if not recs:
        return [Row("roofline/missing", 0.0,
                    "run: python -m repro.launch.dryrun --all "
                    "--out results/dryrun.jsonl")]
    rows = []
    n_ok = n_skip = n_err = 0
    for r in recs:
        key = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            n_skip += 1
            rows.append(Row(key, 0.0, f"SKIP ({r['reason']})"))
            continue
        if r["status"] != "ok":
            n_err += 1
            rows.append(Row(key, 0.0, "ERROR " + r.get("error", "?")[:80]))
            continue
        n_ok += 1
        rf = r["roofline"]
        rows.append(Row(
            key, r.get("compile_s", 0) * 1e6,
            f"compute={rf['compute_s']:.4g}s memory={rf['memory_s']:.4g}s "
            f"collective={rf['collective_s']:.4g}s "
            f"dominant={rf['dominant'].replace('_s','')} "
            f"useful_flop_ratio={rf['useful_flop_ratio']:.3f}"))
    rows.append(Row("roofline/summary", 0.0,
                    f"ok={n_ok} skipped={n_skip} errors={n_err}"))
    return rows
